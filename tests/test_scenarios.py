"""Scenario × target axes: bucketing/dispatch, cache v3 scenario-keyed
roundtrip, v2 -> v3 load-through migration, the campaign orchestrator's
scenarios × targets product (dedupe, resume, loud unknown-target errors),
zero-measurement serve-time dispatch, and memo merge-on-save."""

import json
import os

import pytest

from repro.core import Machine
from repro.kernels import get_kernel
from repro.launch.optimize import (campaign_requests, parse_scenarios,
                                   parse_targets)
from repro.sched import OptimizationSession, cache, make_budgeted_strategy
from repro.sched.backends import SharedMeasureMemo
from repro.sched.cache import ScheduleCache
from repro.sched.scenario import (DEFAULT_BUCKET, MachineTarget, Scenario,
                                  bucket_of, get_target, nearest_bucket,
                                  require_target)
from repro.serve.engine import schedule_plan

TINY = dict(timesteps=64, episode_length=8)


def _tiny_session(tmp_path, stall_db, sub="cache"):
    return OptimizationSession(
        strategy=make_budgeted_strategy("greedy", **TINY),
        cache_dir=str(tmp_path / sub), stall_db=stall_db, verify_seeds=2)


# ---------------------------------------------------------------------------
# scenario model
# ---------------------------------------------------------------------------

def test_scenario_bucketing_parse_and_normalization():
    s = Scenario(batch=12, seq_len=3000, dtype="bfloat16", occupancy="half")
    assert s.dtype == "bf16"                       # alias normalization
    assert s.bucket == "b16_s4096_bf16_half"       # pow2 edges round up
    assert Scenario.parse("256x4096") == Scenario(batch=256, seq_len=4096)
    assert Scenario.parse("8x32768xf32xlow").dtype == "f32"
    assert Scenario(batch=8, seq_len=8192).bucket == \
        Scenario(batch=5, seq_len=4097).bucket      # same bucket cell
    with pytest.raises(ValueError, match="BATCHxSEQ"):
        Scenario.parse("just-one-token")
    with pytest.raises(ValueError, match="occupancy"):
        Scenario(batch=1, seq_len=1, occupancy="over9000")
    assert bucket_of(None) == DEFAULT_BUCKET
    assert bucket_of("b8_s4096_bf16_full") == "b8_s4096_bf16_full"


def test_nearest_bucket_dispatch_metric():
    tuned = ["b8_s4096_bf16_full", "b64_s32768_bf16_half", DEFAULT_BUCKET]
    # exact bucket wins
    assert nearest_bucket(tuned, Scenario(batch=8, seq_len=4096)) == \
        "b8_s4096_bf16_full"
    # nearest by log2 distance on batch/seq
    assert nearest_bucket(tuned, Scenario(batch=12, seq_len=4096)) == \
        "b8_s4096_bf16_full"
    assert nearest_bucket(
        tuned, Scenario(batch=128, seq_len=32768, occupancy="half")) == \
        "b64_s32768_bf16_half"
    # dtype mismatch outweighs any shape distance
    assert nearest_bucket(
        ["b8_s4096_f32_full", "b1024_s1024_bf16_full"],
        Scenario(batch=8, seq_len=4096)) == "b1024_s1024_bf16_full"
    # deterministic tie-break: equal distance resolves lexicographically
    assert nearest_bucket(
        ["b16_s4096_bf16_full", "b4_s4096_bf16_full"],
        Scenario(batch=8, seq_len=4096)) == "b16_s4096_bf16_full"
    # default bucket is the fallback of last resort, never the winner
    assert nearest_bucket([DEFAULT_BUCKET],
                          Scenario(batch=1, seq_len=1)) == DEFAULT_BUCKET
    assert nearest_bucket([], Scenario(batch=1, seq_len=1)) is None


def test_machine_targets_registry():
    assert get_target(None).name == "tpu-tsass-v1"
    assert get_target("tpu-tsass-v2").seed == 1
    # unknown names: get_target admits ad-hoc partitions ...
    adhoc = get_target("my-private-partition")
    assert adhoc.name == "my-private-partition"
    # ... require_target (the --targets contract) fails loudly, listing
    # what is registered
    with pytest.raises(KeyError, match="tpu-tsass-v1"):
        require_target("tpu-tsass-v99")
    # equal-named handles compare equal (dict-key identity), factories
    # excluded from the comparison
    assert MachineTarget("x", machine_factory=Machine) == MachineTarget("x")


# ---------------------------------------------------------------------------
# cache v3: scenario-keyed index + v2 load-through
# ---------------------------------------------------------------------------

def test_cache_v3_scenario_keyed_roundtrip(tmp_path, kernel_programs):
    prog = kernel_programs["softmax"]
    sc = ScheduleCache(str(tmp_path), target="test-target")
    full = Scenario(batch=8, seq_len=4096)
    half = Scenario(batch=64, seq_len=32768, occupancy="half")
    sc.put(cache.Artifact("softmax", "test-target", {"br": 8}, prog,
                          100.0, 90.0, {}, scenario=full.bucket))
    sc.put(cache.Artifact("softmax", "test-target", {"br": 32}, prog,
                          100.0, 80.0, {}, scenario=half.bucket))
    sc.put(cache.Artifact("softmax", "test-target", {"br": 16}, prog,
                          100.0, 95.0, {}))        # default bucket
    assert sc.scenario_buckets("softmax") == sorted(
        [full.bucket, half.bucket, DEFAULT_BUCKET])
    # per-bucket chosen configs are distinct index entries
    assert sc.best_config("softmax", full) == {"br": 8}
    assert sc.best_config("softmax", half) == {"br": 32}
    assert sc.best_config("softmax") == {"br": 16}
    assert sc.lookup_best("softmax", half).optimized_cycles == 80.0
    assert sc.lookup_best("softmax", half).scenario == half.bucket
    # scenario-less lookup keeps resolving the default bucket
    assert sc.lookup_best("softmax").optimized_cycles == 95.0
    idx = cache.load_index(str(tmp_path), "test-target", "softmax")
    assert idx["version"] == 3
    assert len(idx["scenarios"]) == 3
    # the default-bucket entry also populates the legacy "best" field
    assert idx["best"]["config"] == {"br": 16}


def _write_v2_dir(art, cache_dir):
    """Replicate the pre-scenario v2 on-disk format exactly: versioned
    sidecar + index with only entries/best, no scenarios map."""
    key = cache.cache_key(art.kernel, art.target, art.config)
    d = os.path.join(cache_dir, art.target, art.kernel)
    os.makedirs(d, exist_ok=True)
    from repro.core.isa import program_text
    with open(os.path.join(d, f"{key}.tsass"), "w") as f:
        f.write(program_text(art.program) + "\n")
    with open(os.path.join(d, f"{key}.json"), "w") as f:
        json.dump({"version": 2, "kernel": art.kernel, "target": art.target,
                   "config": art.config,
                   "baseline_cycles": art.baseline_cycles,
                   "optimized_cycles": art.optimized_cycles,
                   "meta": art.meta}, f)
    with open(os.path.join(d, "index.json"), "w") as f:
        json.dump({"version": 2, "kernel": art.kernel, "target": art.target,
                   "entries": {key: art.config},
                   "best": {"key": key, "config": art.config,
                            "optimized_cycles": art.optimized_cycles}}, f)
    return key


def test_v2_cache_dir_loads_through_as_default_bucket(tmp_path,
                                                      kernel_programs):
    prog = kernel_programs["softmax"]
    art = cache.Artifact("softmax", "test-target", {"br": 8, "cols": 4096},
                         prog, 100.0, 90.0, {})
    _write_v2_dir(art, str(tmp_path))
    sc = ScheduleCache(str(tmp_path), target="test-target")
    # the v2 best IS the default bucket
    assert sc.scenario_buckets("softmax") == [DEFAULT_BUCKET]
    assert sc.lookup_best("softmax").optimized_cycles == 90.0
    # scenario dispatch on a v2 dir falls back to the default bucket
    got = sc.dispatch("softmax", Scenario(batch=4, seq_len=1024))
    assert got is not None and got.optimized_cycles == 90.0
    # writing a scenario entry migrates the index to v3 without losing
    # the legacy best
    sc.put(cache.Artifact("softmax", "test-target", {"br": 32}, prog,
                          100.0, 70.0, {},
                          scenario=Scenario(batch=4, seq_len=1024).bucket))
    idx = cache.load_index(str(tmp_path), "test-target", "softmax")
    assert idx["version"] == 3
    assert idx["best"]["optimized_cycles"] == 90.0
    scen = cache.index_scenarios(idx)
    assert scen[DEFAULT_BUCKET]["optimized_cycles"] == 90.0
    assert scen["b4_s1024_bf16_full"]["optimized_cycles"] == 70.0
    # ... and the new bucket now wins its own dispatch
    sc2 = ScheduleCache(str(tmp_path), target="test-target")
    assert sc2.dispatch(
        "softmax", Scenario(batch=4, seq_len=1024)).optimized_cycles == 70.0


def test_cache_key_default_bucket_is_byte_identical():
    """The whole v2 compat story: scenario-less keys never changed."""
    legacy = cache.cache_key("k", "t", {"a": 1})
    assert cache.cache_key("k", "t", {"a": 1}, None) == legacy
    assert cache.cache_key("k", "t", {"a": 1}, DEFAULT_BUCKET) == legacy
    assert cache.cache_key("k", "t", {"a": 1},
                           Scenario(batch=8, seq_len=4096)) != legacy


# ---------------------------------------------------------------------------
# scenario-aware spec construction
# ---------------------------------------------------------------------------

def test_make_spec_scenario_changes_spec_and_none_is_legacy():
    kdef = get_kernel("rmsnorm")
    cfg = kdef.configs[0]
    legacy = kdef.make_spec(cfg)                     # positional: untouched
    assert legacy.steps == 4 and legacy.inputs[0].dtype == "bf16"
    scen = Scenario(batch=64, seq_len=32768, dtype="f32", occupancy="low")
    spec = kdef.make_spec(cfg, scenario=scen)
    assert spec.inputs[0].dtype == "f32"
    assert spec.steps != legacy.steps
    # build_spec routes the kwarg only to scenario-aware builders
    from repro.sched.scenario import build_spec
    assert build_spec(kdef.make_spec, cfg, None).steps == legacy.steps
    assert build_spec(lambda c: kdef.make_spec(c), cfg, scen).steps == \
        legacy.steps                                  # legacy builder: no kwarg


def test_kernel_fleet_yields_scenario_pairs():
    from repro.configs import get_config
    from repro.launch.specs import (fleet_scenarios, kernel_fleet,
                                    kernel_fleet_names, shape_scenario)
    cfg = get_config("stablelm-3b", reduced=True)
    pairs = kernel_fleet(cfg)
    names = kernel_fleet_names(cfg)
    assert all(isinstance(n, str) and isinstance(s, Scenario)
               for n, s in pairs)
    assert list(dict.fromkeys(n for n, _ in pairs)) == names
    # one scenario per distinct bucket of the config's supported shapes
    scens = fleet_scenarios(cfg)
    assert len({s.bucket for s in scens}) == len(scens)
    assert {(n, s.bucket) for n, s in pairs} == \
        {(n, s.bucket) for n in names for s in scens}
    # shapes drive the occupancy class: train/prefill saturate, decode
    # rides the batch size
    assert shape_scenario(cfg, "train_4k").occupancy == "full"
    assert shape_scenario(cfg, "decode_32k").occupancy == "half"
    assert shape_scenario(cfg, "long_500k").occupancy == "low"


# ---------------------------------------------------------------------------
# campaign orchestrator
# ---------------------------------------------------------------------------

def test_campaign_requests_product_and_dedupe():
    scens = parse_scenarios("8x4096,64x32768xbf16xhalf")
    tgts = parse_targets("tpu-tsass-v1,tpu-tsass-v2")
    # positional kernel overlapping the fleet-derived unit collapses, as
    # do two scenarios in the same bucket
    units = ([("rmsnorm", None), ("rmsnorm", None)]
             + [("rmsnorm", s) for s in scens]
             + [("rmsnorm", Scenario(batch=5, seq_len=4096))])  # same bucket
    reqs = campaign_requests(units, tgts)
    cells = [(r.kernel, bucket_of(r.scenario), r.target.name) for r in reqs]
    assert len(cells) == len(set(cells)) == 6      # 3 buckets × 2 targets
    assert cells[0] == ("rmsnorm", DEFAULT_BUCKET, "tpu-tsass-v1")
    # no targets: one request per (kernel, bucket) at the session default
    assert len(campaign_requests(units)) == 3
    with pytest.raises(KeyError, match="registered targets"):
        parse_targets("tpu-tsass-v1,definitely-not-a-target")


def test_campaign_two_scenarios_two_targets_distinct_and_resumable(
        tmp_path, stall_db):
    session = _tiny_session(tmp_path, stall_db)
    scens = parse_scenarios("8x4096,64x32768xbf16xhalf")
    tgts = parse_targets("tpu-tsass-v1,tpu-tsass-v2")
    reqs = campaign_requests([("rmsnorm", s) for s in scens], tgts)
    results = session.optimize_many(reqs, max_workers=2)
    assert len(results) == 4
    assert not any(r.from_cache for r in results)
    assert {(r.scenario, r.target) for r in results} == \
        {(s.bucket, t.name) for s in scens for t in tgts}
    # each target partition holds its own per-bucket index entries
    for t in tgts:
        idx = cache.load_index(str(tmp_path / "cache"), t.name, "rmsnorm")
        scen_map = cache.index_scenarios(idx)
        assert sorted(scen_map) == sorted(s.bucket for s in scens)
        assert scen_map[scens[0].bucket]["config"] != \
            scen_map[scens[1].bucket]["config"] or \
            scen_map[scens[0].bucket]["key"] != \
            scen_map[scens[1].bucket]["key"]
    # re-running the identical campaign resumes: every cell a cache hit
    again = session.optimize_many(campaign_requests(
        [("rmsnorm", s) for s in scens], tgts))
    assert all(r.from_cache for r in again)
    # ... including from a cold session (index-driven, not in-memory)
    cold = _tiny_session(tmp_path, stall_db)
    third = cold.optimize_many(campaign_requests(
        [("rmsnorm", s) for s in scens], tgts))
    assert all(r.from_cache for r in third)


def test_deploy_and_schedule_plan_dispatch_zero_measurements(
        tmp_path, stall_db, monkeypatch):
    """The acceptance criterion: serve-time dispatch resolves request
    shapes to the nearest tuned bucket as a pure index lookup — zero
    autotune, zero Machine.run/time."""
    session = _tiny_session(tmp_path, stall_db)
    scens = parse_scenarios("8x4096,64x32768xbf16xhalf")
    session.optimize_many(campaign_requests([("rmsnorm", s) for s in scens]))

    calls = {"run": 0, "time": 0, "autotune": 0}
    real_run, real_time = Machine.run, Machine.time
    import sys
    autotune_mod = sys.modules["repro.sched.autotune"]

    def counting(name, fn):
        def wrapper(*a, **kw):
            calls[name] += 1
            return fn(*a, **kw)
        return wrapper

    monkeypatch.setattr(Machine, "run", counting("run", real_run))
    monkeypatch.setattr(Machine, "time", counting("time", real_time))
    monkeypatch.setattr(autotune_mod, "autotune",
                        counting("autotune", autotune_mod.autotune))

    # fresh session + fresh cache: everything below is index reads only
    fresh = _tiny_session(tmp_path, stall_db)
    near = Scenario(batch=12, seq_len=4096)          # not an exact bucket
    art = fresh.deploy("rmsnorm", scenario=near)
    assert art.scenario == scens[0].bucket           # nearest tuned bucket
    far = Scenario(batch=100, seq_len=32768, occupancy="half")
    assert fresh.deploy("rmsnorm", scenario=far).scenario == scens[1].bucket

    sc = ScheduleCache(str(tmp_path / "cache"))
    plan = schedule_plan([("rmsnorm", near), ("rmsnorm", scens[1]),
                          "softmax"], cache=sc)
    assert plan[("rmsnorm", near.bucket)].scenario == scens[0].bucket
    assert plan[("rmsnorm", scens[1].bucket)].scenario == scens[1].bucket
    assert plan["softmax"] is None                   # never optimized: -O3
    assert calls == {"run": 0, "time": 0, "autotune": 0}


# ---------------------------------------------------------------------------
# memo merge-on-save (concurrent --memo-dir campaigns)
# ---------------------------------------------------------------------------

def test_memo_merge_on_save_unions_concurrent_writers(tmp_path):
    path = str(tmp_path / "memo.pkl")
    a, b = SharedMeasureMemo(), SharedMeasureMemo()
    va = a.view([], owner="ka")
    vb = b.view([], owner="kb")
    va[b"shared"] = 1.0
    va[b"only-a"] = 2.0
    vb[b"shared"] = 1.0          # bit-exact duplicate (same measurement)
    vb[b"only-b"] = 3.0
    assert a.save(path) == 2
    # b saves last but does NOT clobber a's entries: the on-disk file is
    # folded in under the atomic rename
    assert b.save(path) == 3
    merged = SharedMeasureMemo()
    assert merged.load(path) == 3
    mv = merged.view([], owner="kc")
    assert mv.get(b"only-a") == 2.0
    assert mv.get(b"only-b") == 3.0
    assert mv.get(b"shared") == 1.0
    # merge=False restores pure last-writer-wins for tools that want it
    assert a.save(path, merge=False) == 2
    fresh = SharedMeasureMemo()
    fresh.load(path)
    assert fresh.view([], owner="k").get(b"only-b") is None
