"""Compiler-integration pipeline: lowering validity, baseline quality,
autotune, cache round-trip, probabilistic testing, end-to-end optimize."""

import pytest

from repro.core import Machine, analyze
from repro.core.machine import dataflow_reference
from repro.core.ppo import PPOConfig
from repro.kernels import KERNELS
from repro.sched import (CuAsmRL, autotune, cache, lower, naive_schedule,
                         probabilistic_test, schedule)


@pytest.mark.parametrize("name", list(KERNELS))
def test_baseline_schedule_is_valid(name, stall_db):
    kdef = KERNELS[name]
    lk = lower(kdef.make_spec(kdef.configs[0]))
    o3 = schedule(lk)
    nv = naive_schedule(lk)
    # both schedules compute the same dataflow result, timed correctly
    for seed in range(2):
        ref = dataflow_reference(nv, input_seed=seed)
        assert Machine().run(o3, input_seed=seed).outputs == ref, name
        assert Machine().run(nv, input_seed=seed).outputs == ref, name


@pytest.mark.parametrize("name", list(KERNELS))
def test_baseline_beats_naive(name):
    kdef = KERNELS[name]
    lk = lower(kdef.make_spec(kdef.configs[0]))
    m = Machine()
    naive = m.run(naive_schedule(lk)).cycles
    windowed = m.run(schedule(lk)).cycles          # the ptxas stand-in
    unbounded = m.run(schedule(lk, window=None)).cycles
    assert windowed <= naive * 1.01, (name, windowed, naive)
    assert unbounded < naive, (name, unbounded, naive)
    assert unbounded <= windowed


def test_lowering_structure(stall_db):
    kdef = KERNELS["flash_attention"]
    prog = schedule(lower(kdef.make_spec(kdef.configs[0])))
    bases = [i.base for i in prog]
    assert "MXM" in bases and "CPYIN" in bases and "CPYOUT" in bases
    assert any(i.predicated_off() for i in prog)          # @!PT slots
    # .reuse hints appear on dense MXM bursts (matmul kernel)
    mm = schedule(lower(KERNELS["matmul_leakyrelu"].make_spec(
        {"bm": 256, "bn": 128, "bk": 64})))
    assert any(".reuse" in op for i in mm for op in i.operands)
    ana = analyze(prog, stall_db)
    fr = ana.resolution_fractions()
    assert fr["denylist"] > 0                              # Fig. 7 classes
    assert fr["db"] > 0 and fr["infer"] > 0


def test_autotune_selects_best_throughput():
    kdef = KERNELS["matmul_leakyrelu"]
    res = autotune(kdef.make_spec, kdef.configs)
    assert len(res.entries) == len(kdef.configs)
    assert res.best.work_per_cycle == max(e.work_per_cycle
                                          for e in res.entries)


def test_cache_roundtrip(tmp_path, kernel_programs):
    prog = kernel_programs["softmax"]
    art = cache.Artifact(kernel="softmax", target="test-target",
                         config={"br": 8, "cols": 4096}, program=prog,
                         baseline_cycles=100.0, optimized_cycles=90.0,
                         meta={"note": "x"})
    cache.save(art, str(tmp_path))
    back = cache.load("softmax", "test-target", {"br": 8, "cols": 4096},
                      str(tmp_path))
    assert back is not None and back.speedup == pytest.approx(100.0 / 90.0)
    from repro.core.isa import program_text
    assert program_text(back.program) == program_text(prog)
    assert cache.load("softmax", "other", {"br": 8, "cols": 4096},
                      str(tmp_path)) is None


def test_probabilistic_testing_catches_corruption(kernel_programs):
    prog = kernel_programs["rmsnorm"]
    ok = probabilistic_test(prog, prog, n_seeds=3)
    assert ok.ok
    # force an illegal reorder: swap a dependent pair by hand
    bad = list(prog)
    idx = next(i for i in range(1, len(bad))
               if (bad[i - 1].defs or frozenset()) & (bad[i].uses or frozenset()))
    bad[idx - 1], bad[idx] = bad[idx], bad[idx - 1]
    res = probabilistic_test(prog, bad, n_seeds=3)
    assert not res.ok and res.failures


def test_cuasmrl_optimize_and_deploy(tmp_path, stall_db):
    """End-to-end §4.2 workflow on a tiny PPO budget: optimize -> cached
    artifact -> deploy-time lookup without training."""
    kdef = KERNELS["rmsnorm"]
    ppo = PPOConfig(total_timesteps=512, num_envs=4, num_steps=32,
                    episode_length=24, seed=0)
    opt = CuAsmRL(kdef, ppo=ppo, cache_dir=str(tmp_path), stall_db=stall_db,
                  verify_seeds=2)
    art = opt.optimize()
    assert art.optimized_cycles <= art.baseline_cycles
    art2 = opt.deploy()
    assert art2.optimized_cycles == art.optimized_cycles
    # second optimize() call is a cache hit (no retraining)
    art3 = opt.optimize()
    assert art3.optimized_cycles == art.optimized_cycles
