"""Action-masking properties — the safety core of the whole paper.

Property 1 (semantic safety): any sequence of masked actions leaves the
machine's observable output identical to the dataflow reference, across
kernels and randomized input seeds (this is the paper's probabilistic
testing run adversarially against the masking rules).

Property 2 (fast == reference): the environment's O(1) fast masking agrees
exactly with the literal §3.5/Algorithm-1 transcription at every step of
random games.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test dep (see pyproject [test])
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import AssemblyGame, Machine  # noqa: E402
from repro.core.machine import dataflow_reference  # noqa: E402

KERNELS_UNDER_TEST = ["rmsnorm", "flash_attention", "matmul_leakyrelu", "ssd"]


@pytest.mark.parametrize("kernel", KERNELS_UNDER_TEST)
def test_masked_walks_never_corrupt(kernel, stall_db, kernel_programs):
    prog = kernel_programs[kernel]
    env = AssemblyGame(prog, stall_db=stall_db, episode_length=64)
    rng = np.random.default_rng(0)
    for seed in range(3):
        ref = dataflow_reference(prog, input_seed=seed)
        env.reset()
        for _ in range(64):
            va = env.valid_actions()
            if not va:
                break
            env.step(int(rng.choice(va)))
        got = Machine().run(env.program, input_seed=seed).outputs
        assert got == ref, f"{kernel} corrupted under masked walk"


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_fast_mask_equals_reference(seed, stall_db, kernel_programs):
    prog = kernel_programs["rmsnorm"]
    fast = AssemblyGame(prog, stall_db=stall_db, episode_length=40)
    slow = AssemblyGame(prog, stall_db=stall_db, episode_length=40,
                        use_fast_mask=False)
    fast.reset(), slow.reset()
    rng = np.random.default_rng(seed)
    for _ in range(25):
        mf, ms = fast.action_mask(), slow.action_mask()
        assert np.array_equal(mf, ms)
        va = np.where(mf > 0)[0]
        if len(va) == 0:
            break
        a = int(rng.choice(va))
        fast.step(a), slow.step(a)


def test_group_order_is_pinned(stall_db, kernel_programs):
    """Consecutive-DMA groups (the paper's LDGSTS heuristic) never reorder
    among themselves."""
    prog = kernel_programs["matmul_leakyrelu"]
    env = AssemblyGame(prog, stall_db=stall_db, episode_length=64)
    rng = np.random.default_rng(1)

    def group_orders():
        seen = {}
        for pos, ins in enumerate(env.program):
            if ins.group is not None:
                seen.setdefault(ins.group, []).append(id(ins))
        return seen

    env.reset()
    before = group_orders()
    for _ in range(50):
        va = env.valid_actions()
        if not va:
            break
        env.step(int(rng.choice(va)))
    assert group_orders() == before


def test_waiter_never_above_setter(stall_db, kernel_programs):
    """Barrier rule: after any masked walk, every waiter still follows at
    least one setter of each semaphore it waits on."""
    prog = kernel_programs["fused_ff"]
    env = AssemblyGame(prog, stall_db=stall_db, episode_length=64)
    rng = np.random.default_rng(2)
    env.reset()
    for _ in range(50):
        va = env.valid_actions()
        if not va:
            break
        env.step(int(rng.choice(va)))
    seen_setters = set()
    for ins in env.program:
        for s in ins.ctrl.wait_mask:
            assert s in seen_setters, "waiter drifted above all its setters"
        if ins.ctrl.read_bar is not None:
            seen_setters.add(ins.ctrl.read_bar)
        if ins.ctrl.write_bar is not None:
            seen_setters.add(ins.ctrl.write_bar)


def test_no_crossing_labels(stall_db, kernel_programs):
    prog = kernel_programs["softmax"]
    env = AssemblyGame(prog, stall_db=stall_db, episode_length=64)
    blocks_before = [env.deps.block[int(i)] for i in env.id_at]
    rng = np.random.default_rng(3)
    env.reset()
    for _ in range(40):
        va = env.valid_actions()
        if not va:
            break
        env.step(int(rng.choice(va)))
    blocks_after = [env.deps.block[int(i)] for i in env.id_at]
    assert blocks_before == blocks_after
