"""Session API (backend-pluggable OptimizationSession + fleet-scale
optimize_many): bit-exact equivalence with the legacy serial CuAsmRL path,
cross-kernel memo sharing, zero-measurement deploy, cache v1->v2
migration, strategy/backend plumbing, kernel registry, and the incremental
action-mask invalidation."""

import json
import os
import warnings

import numpy as np
import pytest

from repro.core import Machine
from repro.core.env import AssemblyGame
from repro.core.isa import program_text
from repro.core.ppo import PPOConfig
from repro.kernels import (KERNELS, KernelDef, get_kernel, register_kernel,
                           unregister_kernel)
from repro.sched import (CuAsmRL, FastTimingBackend, OptimizationSession,
                         OptimizeRequest, OracleBackend, PooledBackend,
                         cache)
from repro.sched.cache import CacheVersionError, ScheduleCache

TINY_PPO = dict(total_timesteps=256, num_envs=4, num_steps=16,
                episode_length=12, seed=0)


def _legacy(kdef, tmp_path, stall_db, sub):
    """One kernel through the legacy serial CuAsmRL path (own session,
    own memo — no cross-kernel sharing)."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        opt = CuAsmRL(kdef, ppo=PPOConfig(**TINY_PPO),
                      cache_dir=str(tmp_path / sub), stall_db=stall_db,
                      verify_seeds=2)
    return opt.optimize(force=True)


@pytest.fixture
def alias_kernel():
    """A second registry name for the rmsnorm spec — the fleet scenario of
    one kernel appearing under several workloads."""
    kdef = get_kernel("rmsnorm")
    alias = register_kernel(KernelDef("rmsnorm_alias", kdef.make_spec,
                                      kdef.configs))
    yield alias
    unregister_kernel("rmsnorm_alias")


def test_optimize_many_bitexact_vs_legacy_with_cross_kernel_hits(
        tmp_path, stall_db, alias_kernel):
    """The acceptance criterion: a fleet through one session returns
    bit-exact best cycles/programs vs running each kernel through the
    legacy serial CuAsmRL path with the same seeds, while the shared memo
    records cross-kernel hits."""
    legacy = {name: _legacy(get_kernel(name), tmp_path, stall_db, "legacy")
              for name in ("rmsnorm", "softmax")}

    session = OptimizationSession(stall_db=stall_db,
                                  cache_dir=str(tmp_path / "fleet"),
                                  verify_seeds=2)
    ppo = PPOConfig(**TINY_PPO)
    fleet = session.optimize_many(
        [OptimizeRequest(kernel=k, ppo=ppo, force=True)
         for k in ("rmsnorm", "rmsnorm_alias", "softmax")])

    by_name = {r.kernel: r for r in fleet}
    for name in ("rmsnorm", "softmax"):
        art, ref = by_name[name].artifact, legacy[name]
        assert art.optimized_cycles == ref.optimized_cycles, name
        assert art.baseline_cycles == ref.baseline_cycles, name
        assert program_text(art.program) == program_text(ref.program), name
        assert art.config == ref.config, name
    # the alias is the same program + seeds, so the same search trajectory
    assert (by_name["rmsnorm_alias"].artifact.optimized_cycles
            == legacy["rmsnorm"].optimized_cycles)
    # ... and every one of its measurements was served by rmsnorm's entries
    stats = session.memo.stats()
    assert stats["cross_kernel_hits"] > 0
    assert stats["hits"] > stats["cross_kernel_hits"]


def test_optimize_many_concurrent_matches_serial(tmp_path, stall_db,
                                                 alias_kernel):
    """Thread-pooled fleets return the same measured values (the memo is
    bit-exact, so interleaving cannot change cycles)."""
    ppo = PPOConfig(**TINY_PPO)
    names = ("rmsnorm", "rmsnorm_alias")
    serial = OptimizationSession(stall_db=stall_db,
                                 cache_dir=str(tmp_path / "s"),
                                 verify_seeds=2).optimize_many(
        [OptimizeRequest(kernel=k, ppo=ppo, force=True) for k in names])
    threaded = OptimizationSession(stall_db=stall_db,
                                   cache_dir=str(tmp_path / "t"),
                                   verify_seeds=2).optimize_many(
        [OptimizeRequest(kernel=k, ppo=ppo, force=True) for k in names],
        max_workers=2)
    for a, b in zip(serial, threaded):
        assert a.kernel == b.kernel
        assert a.artifact.optimized_cycles == b.artifact.optimized_cycles
        assert program_text(a.artifact.program) == \
            program_text(b.artifact.program)


def test_deploy_runs_zero_measurements(tmp_path, stall_db, monkeypatch):
    """Deploy is pure lookup: no autotune, no Machine.run/time (the legacy
    class re-ran the whole grid search per deploy())."""
    session = OptimizationSession(stall_db=stall_db, cache_dir=str(tmp_path),
                                  verify_seeds=2, strategy="greedy")
    optimized = session.optimize(OptimizeRequest(kernel="rmsnorm"))

    calls = {"run": 0, "time": 0, "autotune": 0}
    real_run, real_time = Machine.run, Machine.time
    import sys
    # the package re-exports the function under the same name, so reach
    # the module itself (what session.py/api.py call through)
    autotune_mod = sys.modules["repro.sched.autotune"]

    def counting(name, fn):
        def wrapper(*a, **kw):
            calls[name] += 1
            return fn(*a, **kw)
        return wrapper

    monkeypatch.setattr(Machine, "run", counting("run", real_run))
    monkeypatch.setattr(Machine, "time", counting("time", real_time))
    monkeypatch.setattr(autotune_mod, "autotune",
                        counting("autotune", autotune_mod.autotune))

    # a *fresh* session (cold LRU): still zero measurement work
    fresh = OptimizationSession(stall_db=stall_db, cache_dir=str(tmp_path))
    art = fresh.deploy("rmsnorm")
    assert art.optimized_cycles == optimized.artifact.optimized_cycles
    assert program_text(art.program) == \
        program_text(optimized.artifact.program)
    # the legacy shim's deploy() goes through the same index path
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        shim = CuAsmRL(get_kernel("rmsnorm"), cache_dir=str(tmp_path),
                       stall_db=stall_db)
    art2 = shim.deploy()
    assert art2.optimized_cycles == art.optimized_cycles
    assert calls == {"run": 0, "time": 0, "autotune": 0}
    # second lookup is served by the in-memory LRU
    before = fresh.cache.stats()["disk_loads"]
    fresh.deploy("rmsnorm")
    assert fresh.cache.stats()["disk_loads"] == before
    assert fresh.cache.stats()["hits"] > 0


def _write_v1_artifact(art, cache_dir):
    """Replicate the pre-v2 on-disk format: flat tsass + sidecar without a
    version field and without any index.json."""
    key = cache.cache_key(art.kernel, art.target, art.config)
    d = os.path.join(cache_dir, art.target, art.kernel)
    os.makedirs(d, exist_ok=True)
    with open(os.path.join(d, f"{key}.tsass"), "w") as f:
        f.write(program_text(art.program) + "\n")
    with open(os.path.join(d, f"{key}.json"), "w") as f:
        json.dump({"kernel": art.kernel, "target": art.target,
                   "config": art.config,
                   "baseline_cycles": art.baseline_cycles,
                   "optimized_cycles": art.optimized_cycles,
                   "meta": art.meta}, f)
    return d, key


def test_cache_v1_artifacts_load_through_schedule_cache(tmp_path,
                                                        kernel_programs):
    prog = kernel_programs["softmax"]
    art = cache.Artifact(kernel="softmax", target="test-target",
                         config={"br": 8, "cols": 4096}, program=prog,
                         baseline_cycles=100.0, optimized_cycles=90.0,
                         meta={"note": "x"})
    _write_v1_artifact(art, str(tmp_path))
    sc = ScheduleCache(str(tmp_path), target="test-target")
    back = sc.lookup("softmax", art.config)
    assert back is not None
    assert back.optimized_cycles == art.optimized_cycles
    assert back.baseline_cycles == art.baseline_cycles
    assert program_text(back.program) == program_text(prog)
    # v1 dir, single artifact, no index: lookup_best is still unambiguous
    best = sc.lookup_best("softmax")
    assert best is not None and best.optimized_cycles == 90.0
    # repeated lookups resolve through the memoized config + LRU: no
    # re-listing / re-parsing per call even on pre-index dirs
    loads = sc.stats()["disk_loads"]
    # mutating a returned artifact never poisons the LRU
    best.program.clear()
    assert len(sc.lookup_best("softmax").program) == len(prog)
    assert sc.stats()["disk_loads"] == loads


def test_cache_unknown_version_and_corruption_fail_loudly(tmp_path,
                                                          kernel_programs):
    prog = kernel_programs["softmax"]
    art = cache.Artifact(kernel="softmax", target="test-target",
                         config={"br": 8, "cols": 4096}, program=prog,
                         baseline_cycles=100.0, optimized_cycles=90.0,
                         meta={})
    d, key = _write_v1_artifact(art, str(tmp_path))
    sidecar = os.path.join(d, f"{key}.json")
    with open(sidecar) as f:
        payload = json.load(f)
    payload["version"] = 99
    with open(sidecar, "w") as f:
        json.dump(payload, f)
    sc = ScheduleCache(str(tmp_path), target="test-target")
    with pytest.raises(CacheVersionError):
        sc.lookup("softmax", art.config)
    with open(sidecar, "w") as f:
        f.write("{not json")
    with pytest.raises(CacheVersionError):
        sc.lookup("softmax", art.config)
    # module-level load() fails just as loudly (no silent miss)
    with pytest.raises(CacheVersionError):
        cache.load("softmax", "test-target", art.config, str(tmp_path))
    # a genuinely absent artifact is still a miss, not an error
    assert sc.lookup("softmax", {"other": 1}) is None


def test_v2_roundtrip_writes_index_and_best(tmp_path, kernel_programs):
    prog = kernel_programs["softmax"]
    sc = ScheduleCache(str(tmp_path), target="test-target")
    a1 = cache.Artifact("softmax", "test-target", {"br": 8}, prog,
                        100.0, 90.0, {})
    a2 = cache.Artifact("softmax", "test-target", {"br": 16}, prog,
                        100.0, 95.0, {})
    sc.put(a1, best=True)
    sc.put(a2, best=False)              # an entry, not the chosen config
    assert sc.best_config("softmax") == {"br": 8}
    assert sc.lookup_best("softmax").optimized_cycles == 90.0
    idx = cache.load_index(str(tmp_path), "test-target", "softmax")
    assert idx["version"] == cache.CACHE_VERSION
    assert len(idx["entries"]) == 2


def test_baseline_strategies_and_backends(tmp_path, stall_db):
    """Greedy / random strategies and the oracle / pooled backends run the
    whole optimize pipeline and never lose to the -O3 baseline."""
    outs = {}
    for strategy in ("greedy", "random"):
        s = OptimizationSession(stall_db=stall_db,
                                cache_dir=str(tmp_path / strategy),
                                strategy=strategy, verify_seeds=2)
        r = s.optimize(OptimizeRequest(kernel="softmax", force=True))
        assert r.artifact.optimized_cycles <= r.artifact.baseline_cycles
        assert r.strategy == strategy
        assert r.artifact.meta["strategy"] == strategy
        outs[strategy] = r
    # oracle backend: same greedy trajectory, measured by Machine.run
    oracle = OptimizationSession(backend=OracleBackend(),
                                 stall_db=stall_db,
                                 cache_dir=str(tmp_path / "oracle"),
                                 strategy="greedy", verify_seeds=2)
    ro = oracle.optimize(OptimizeRequest(kernel="softmax", force=True))
    assert ro.artifact.optimized_cycles == \
        outs["greedy"].artifact.optimized_cycles
    assert oracle.memo is None          # no sharing on the oracle path
    pooled = OptimizationSession(backend=PooledBackend(workers=2),
                                 stall_db=stall_db,
                                 cache_dir=str(tmp_path / "pooled"),
                                 strategy="greedy", verify_seeds=2)
    rp = pooled.optimize(OptimizeRequest(kernel="softmax", force=True))
    assert rp.artifact.optimized_cycles == \
        outs["greedy"].artifact.optimized_cycles


def test_make_budgeted_strategy_honours_flags():
    from repro.sched import make_budgeted_strategy
    g = make_budgeted_strategy("greedy", timesteps=100_000, episode_length=40)
    assert g.max_steps == 40
    r = make_budgeted_strategy("random", timesteps=1000, episode_length=40)
    assert r.episodes == 25 and r.episode_length == 40
    p = make_budgeted_strategy("ppo", timesteps=1024, episode_length=40)
    assert p.ppo.total_timesteps == 1024
    assert p.ppo.episode_length == 40
    assert p.ppo.num_steps == 128          # clamped rollout length
    with pytest.raises(KeyError):
        make_budgeted_strategy("definitely_not_a_strategy")


def test_memo_eviction_is_bounded():
    from repro.sched import SharedMeasureMemo
    memo = SharedMeasureMemo(max_entries=16)
    view = memo.view([], owner="k")
    for i in range(100):
        view[bytes([i])] = float(i)
    assert len(memo) <= 16
    assert memo.stats()["evictions"] > 0
    # surviving entries still serve hits
    assert view.get(bytes([99])) == 99.0


def test_noisy_autotune_time_fn_matches_legacy_machine():
    """For noisy machines the grid sweep reuses one machine, so each config
    draws independent noise from the same stream the legacy
    ``autotune(..., machine=factory())`` path used."""
    from repro.core.microbench import _probe_program
    prog = _probe_program("SADD", 4)   # noise multiplies its cycle count
    backend = FastTimingBackend(lambda: Machine(noise=0.05, seed=3))
    assert not backend.deterministic
    assert backend.memo_view(prog, "k") is None   # memo disabled (noise)
    fn = backend.autotune_time_fn()
    legacy = Machine(noise=0.05, seed=3)
    draws = [fn(prog) for _ in range(4)]
    assert draws == [legacy.time(prog) for _ in range(4)]
    assert len(set(draws)) > 1       # independent noise per grid point


def test_kernel_registry():
    assert "rmsnorm" in KERNELS
    kdef = get_kernel("rmsnorm")
    assert kdef.name == "rmsnorm"
    with pytest.raises(KeyError, match="unknown kernel"):
        get_kernel("definitely_not_registered")
    with pytest.raises(TypeError):
        register_kernel("not-a-kerneldef")
    extra = register_kernel(KernelDef("tmp_test_kernel", kdef.make_spec,
                                      kdef.configs))
    try:
        assert get_kernel("tmp_test_kernel") is extra
    finally:
        unregister_kernel("tmp_test_kernel")
    assert "tmp_test_kernel" not in KERNELS


def test_shared_backend_across_sessions(tmp_path, stall_db):
    """Two sessions sharing one backend share the memo (multi-tenant
    fleet); entries written by the first serve the second."""
    backend = FastTimingBackend()
    s1 = OptimizationSession(backend=backend, stall_db=stall_db,
                             cache_dir=str(tmp_path / "a"),
                             strategy="greedy", verify_seeds=2)
    s1.optimize(OptimizeRequest(kernel="rmsnorm", force=True))
    hits_before = backend.memo.stats()["hits"]
    s2 = OptimizationSession(backend=backend, stall_db=stall_db,
                             cache_dir=str(tmp_path / "b"),
                             strategy="greedy", verify_seeds=2)
    s2.optimize(OptimizeRequest(kernel="rmsnorm", force=True))
    assert backend.memo.stats()["hits"] > hits_before


@pytest.mark.parametrize("kernel,hops", [("rmsnorm", (1,)),
                                         ("flash_attention", (1, 2))])
def test_incremental_mask_matches_reference(kernel, hops, stall_db,
                                            kernel_programs):
    """The per-position swap-ok cache with dirty-set invalidation agrees
    with the literal §3.5/Algorithm-1 reference at every step of seeded
    random games (the non-hypothesis twin of the masking property test)."""
    prog = kernel_programs[kernel]
    fast = AssemblyGame(prog, stall_db=stall_db, episode_length=24,
                        hop_sizes=hops)
    ref = AssemblyGame(prog, stall_db=stall_db, episode_length=24,
                       hop_sizes=hops, use_fast_mask=False)
    rng = np.random.default_rng(1)
    for _ in range(3):
        fast.reset()
        ref.reset()
        for _ in range(24):
            mf, mr = fast.action_mask(), ref.action_mask()
            assert np.array_equal(mf, mr)
            va = np.flatnonzero(mf)
            if va.size == 0:
                break
            a = int(rng.choice(va))
            _, _, done, _ = fast.step(a)
            ref.step(a)
            if done:
                break
    assert fast.best_cycles == ref.best_cycles


# ---------------------------------------------------------------------------
# disk-backed SharedMeasureMemo (fleet warm-starts across campaigns)
# ---------------------------------------------------------------------------

def test_memo_save_load_roundtrip_warm_starts(tmp_path, stall_db):
    from repro.sched.backends import SharedMeasureMemo
    backend = FastTimingBackend()
    session = OptimizationSession(backend=backend, strategy="greedy",
                                  cache_dir=str(tmp_path / "c"),
                                  stall_db=stall_db)
    session.optimize(OptimizeRequest(kernel="rmsnorm"))
    memo = backend.memo
    assert len(memo) > 0
    path = str(tmp_path / "memo.pkl")
    assert memo.save(path) == len(memo)

    fresh = SharedMeasureMemo()
    assert fresh.load(path) == len(memo)
    assert len(fresh) == len(memo)
    # same entries, bit-exact values, under re-interned fingerprints
    assert sorted(c for c, _ in fresh._data.values()) == \
        sorted(c for c, _ in memo._data.values())

    # a campaign warm-started from the persisted memo re-times nothing it
    # already measured: the baseline read is a pure hit
    warm = FastTimingBackend(memo=fresh)
    session2 = OptimizationSession(backend=warm, strategy="greedy",
                                   cache_dir=str(tmp_path / "c2"),
                                   stall_db=stall_db)
    session2.optimize(OptimizeRequest(kernel="rmsnorm"))
    assert fresh.stats()["hits"] > 0
    # loading twice merges idempotently
    assert fresh.load(path) == 0


def test_memo_corrupt_and_unknown_versions_fail_loudly(tmp_path):
    import pickle
    from repro.sched.backends import MemoVersionError, SharedMeasureMemo
    memo = SharedMeasureMemo()
    bad = tmp_path / "bad.pkl"
    bad.write_bytes(b"not a pickle at all")
    with pytest.raises(MemoVersionError, match="corrupt"):
        memo.load(str(bad))
    wrong = tmp_path / "wrong.pkl"
    with open(wrong, "wb") as f:
        pickle.dump({"format": "something-else"}, f)
    with pytest.raises(MemoVersionError, match="not a"):
        memo.load(str(wrong))
    future = tmp_path / "future.pkl"
    with open(future, "wb") as f:
        pickle.dump({"format": "repro-measure-memo", "version": 99,
                     "programs": []}, f)
    with pytest.raises(MemoVersionError, match="version"):
        memo.load(str(future))
    assert len(memo) == 0
