"""Parser/ISA unit tests: text round-trip, Eq. (2) operand expansion,
control codes, memory effects."""

import pytest

pytest.importorskip("hypothesis")  # optional test dep (see pyproject [test])
from hypothesis import given, strategies as st  # noqa: E402

from repro.core.isa import program_text  # noqa: E402
from repro.core.parser import (adjacent_register, expand_register,  # noqa: E402
                               memory_effects, parse_line, parse_program)


def test_adjacent_register_matches_paper_eq2():
    # base = n//2; mod = n%2; flip = 1-mod; adj = base*2 + flip
    assert adjacent_register(10) == 11
    assert adjacent_register(11) == 10
    assert adjacent_register(0) == 1
    assert adjacent_register(219) == 218


@given(st.integers(min_value=0, max_value=254))
def test_adjacent_register_is_involution_and_pairs(n):
    adj = adjacent_register(n)
    assert adjacent_register(adj) == n
    assert abs(adj - n) == 1
    assert {n, adj} == {2 * (n // 2), 2 * (n // 2) + 1}


def test_expand_register_64_suffix():
    assert expand_register("R10.64") == frozenset({"R10", "R11"})
    assert expand_register("R11.64") == frozenset({"R10", "R11"})
    assert expand_register("R7") == frozenset({"R7"})
    assert expand_register("RZ") == frozenset()
    assert expand_register("desc[UR16][R44.64]") == \
        frozenset({"UR16", "R44", "R45"})


def test_parse_line_full_syntax():
    line = ("[B--2---:R1:W3:Y:S04] @!PT CPYIN.4096 [UR2+0x4000], "
            "desc[UR16][R10.64] ; // tile=in_a:2 grp=7")
    ins = parse_line(line)
    assert ins.ctrl.wait_mask == frozenset({2})
    assert ins.ctrl.read_bar == 1 and ins.ctrl.write_bar == 3
    assert ins.ctrl.yield_flag and ins.ctrl.stall == 4
    assert ins.pred == "@!PT" and ins.predicated_off()
    assert ins.base == "CPYIN" and ins.opcode == "CPYIN.4096"
    assert ins.tile == ("in_a", 2) and ins.group == 7
    assert "R10" in ins.uses and "R11" in ins.uses and "UR16" in ins.uses


def test_roundtrip_preserves_program(kernel_programs):
    for name, prog in kernel_programs.items():
        text = program_text(prog)
        re_prog = parse_program(text)
        assert program_text(re_prog) == text, name
        for a, b in zip(prog, re_prog):
            assert a.defs == b.defs and a.uses == b.uses
            assert a.tile == b.tile and a.group == b.group


def test_memory_effects_cpyout_reads_vmem_writes_hbm():
    ins = parse_line("[B------:R0:W-:-:S01] CPYOUT.4096 "
                     "desc[UR16][R8.64+0x0], R40 ; // tile=out_y:0")
    eff = dict(memory_effects(ins))
    assert eff[("tile", "out_y", 0)] is False          # VMEM read
    writes = [c for c, w in memory_effects(ins) if w]
    assert len(writes) == 1 and writes[0][0] == "addr"  # HBM write


def test_mxm_accumulator_is_read_modify_write():
    ins = parse_line("[B------:R-:W-:-:S02] MXM R200, R33.reuse, R35 ;")
    assert "R200" in ins.defs and "R200" in ins.uses
    assert "R33" in ins.uses and "R35" in ins.uses


def test_unknown_opcode_rejected():
    with pytest.raises(ValueError):
        parse_line("[B------:R-:W-:-:S01] FROB R1, R2 ;")
