"""End-to-end behaviour tests for the paper's system: the full CuAsmRL
workflow (microbench -> autotune -> game -> verify -> cache -> deploy) and
the training framework around it."""

from repro.core import Machine
from repro.core.ppo import PPOConfig
from repro.kernels import KERNELS
from repro.sched.api import CuAsmRL


def test_full_workflow_produces_valid_faster_schedule(tmp_path, stall_db):
    kdef = KERNELS["fused_ff"]
    ppo = PPOConfig(total_timesteps=2048, num_envs=8, num_steps=64,
                    episode_length=64, seed=0, warm_start=True)
    opt = CuAsmRL(kdef, ppo=ppo, cache_dir=str(tmp_path), stall_db=stall_db,
                  verify_seeds=3)
    art = opt.optimize(force=True)
    # never slower than the baseline, and semantically identical
    assert art.optimized_cycles <= art.baseline_cycles
    m = Machine()
    for seed in range(3):
        ref_out = m.run(art.program, input_seed=seed).outputs
        assert ref_out  # non-empty observable state
    # deploy path returns the same artifact without retraining
    art2 = opt.deploy()
    assert art2.optimized_cycles == art.optimized_cycles


def test_training_statistics_shape(stall_db):
    """Fig. 12 reproduction: KL and entropy are logged per update and
    entropy trends down as the policy converges."""
    from repro.core.game import train_on_program
    from repro.sched import lower, schedule
    kdef = KERNELS["rmsnorm"]
    prog = schedule(lower(kdef.make_spec(kdef.configs[0])))
    cfg = PPOConfig(total_timesteps=4096, num_envs=8, num_steps=64,
                    episode_length=48, seed=0)
    res = train_on_program(prog, stall_db=stall_db, cfg=cfg)
    ent = [r["entropy"] for r in res.stats]
    assert len(ent) == cfg.num_updates
    assert ent[-1] <= ent[0] + 0.05   # converging policy
