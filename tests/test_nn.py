"""NN substrate numerics: attention variants, MoE, MLA, SSM."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import nn
from repro.kernels import ref
from repro.nn.core import init_params


def _keys(n, seed=0):
    return jax.random.split(jax.random.PRNGKey(seed), n)


def test_chunked_attention_matches_oracle():
    B, S, H, KH, D = 2, 256, 8, 2, 32
    ks = _keys(3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KH, D))
    v = jax.random.normal(ks[2], (B, S, KH, D))
    out = nn.chunked_attention(q, k, v, causal=True, chunk=64)
    kr = jnp.repeat(k, H // KH, axis=2).transpose(0, 2, 1, 3)
    vr = jnp.repeat(v, H // KH, axis=2).transpose(0, 2, 1, 3)
    want = ref.flash_attention(q.transpose(0, 2, 1, 3), kr, vr,
                               causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("window", [16, 64])
def test_sliding_window_attention(window):
    B, S, H, D = 1, 128, 4, 16
    ks = _keys(3, 1)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    out = nn.chunked_attention(q, k, v, causal=True, window=window, chunk=32)
    # oracle with an explicit banded mask
    qf = q.transpose(0, 2, 1, 3) * D ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, k.transpose(0, 2, 1, 3))
    pos = jnp.arange(S)
    mask = (pos[None, :] <= pos[:, None]) & (pos[None, :] > pos[:, None] - window)
    logits = jnp.where(mask[None, None], logits, -1e30)
    want = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(logits, -1),
                      v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_decode_attention_matches_prefill_column():
    B, S, H, KH, D = 2, 96, 8, 4, 16
    ks = _keys(3, 2)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KH, D))
    v = jax.random.normal(ks[2], (B, S, KH, D))
    full = nn.chunked_attention(q, k, v, causal=True, chunk=32)
    for pos in (0, 31, 95):
        od = nn.decode_attention(q[:, pos:pos + 1], k, v, pos)
        np.testing.assert_allclose(np.asarray(od[:, 0]),
                                   np.asarray(full[:, pos]), atol=2e-5)


def test_update_cache_touches_one_position():
    cache = jnp.zeros((2, 16, 4, 8))
    new = jnp.ones((2, 1, 4, 8))
    out = nn.update_cache(cache, new, 5)
    assert float(out[:, 5].sum()) == 2 * 4 * 8
    assert float(out.sum()) == 2 * 4 * 8


def test_moe_dense_routing_is_topk():
    cfg = nn.MoEConfig(n_experts=8, top_k=2, d_model=16, d_ff=32)
    p = init_params(nn.moe_spec(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    from repro.nn.moe import router_probs
    gate_vals, gate_idx, probs = router_probs(p, x, cfg)
    assert gate_idx.shape == (2, 8, 2)
    np.testing.assert_allclose(np.asarray(probs.sum(-1)), 1.0, atol=1e-5)
    y = nn.apply_moe_dense(p, x, cfg)
    assert y.shape == x.shape and bool(jnp.isfinite(y).all())


def test_moe_shared_experts_added():
    cfg = nn.MoEConfig(n_experts=4, top_k=1, d_model=16, d_ff=32,
                       n_shared=1, shared_d_ff=32)
    p = init_params(nn.moe_spec(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 16))
    y = nn.apply_moe_dense(p, x, cfg)
    # zeroing the shared expert changes the output
    p2 = jax.tree.map(lambda a: a, p)
    p2["shared"] = jax.tree.map(jnp.zeros_like, p["shared"])
    y2 = nn.apply_moe_dense(p2, x, cfg)
    assert not np.allclose(np.asarray(y), np.asarray(y2))


def test_mla_decode_equals_train():
    cfg = nn.MLAConfig(d_model=64, n_heads=4, kv_lora_rank=32,
                       qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
    p = init_params(nn.mla_spec(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 64)) * 0.5
    y_train = nn.apply_mla(p, x, cfg, chunk=5)
    cache = nn.init_mla_cache(cfg, 2, 10, dtype=jnp.float32)
    outs = []
    for t in range(10):
        yt, cache = nn.apply_mla_decode(p, x[:, t:t + 1], cache, t, cfg)
        outs.append(yt)
    np.testing.assert_allclose(np.asarray(y_train),
                               np.asarray(jnp.concatenate(outs, 1)),
                               atol=3e-5)


def test_ssm_decode_equals_parallel():
    cfg = nn.SSMConfig(d_model=32, d_inner=64, n_heads=4, head_p=16,
                       n_groups=2, d_state=16)
    p = init_params(nn.ssm_spec(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 32)) * 0.5
    y_par = nn.apply_ssm(p, x, cfg)
    cache = nn.init_ssm_cache(cfg, 2)
    outs = []
    for t in range(12):
        yt, cache = nn.apply_ssm_decode(p, x[:, t:t + 1], cache, cfg)
        outs.append(yt)
    np.testing.assert_allclose(np.asarray(y_par),
                               np.asarray(jnp.concatenate(outs, 1)),
                               atol=3e-5)


def test_rope_relative_property():
    """Attention logits under RoPE depend only on relative positions."""
    D = 16
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, D))
    def logit(qp, kp):
        qr = nn.apply_rope(q, jnp.array([[qp]]))
        kr = nn.apply_rope(k, jnp.array([[kp]]))
        return float(jnp.sum(qr * kr))
    assert logit(5, 3) == pytest.approx(logit(105, 103), abs=1e-4)
