"""Beyond-paper game variants (DESIGN.md §2.2): warm starts and macro moves
inherit the masking safety guarantees unchanged."""

import numpy as np

from repro.core import AssemblyGame, Machine
from repro.core.machine import dataflow_reference


def test_macro_moves_preserve_semantics(stall_db, kernel_programs):
    prog = kernel_programs["fused_ff"]
    env = AssemblyGame(prog, stall_db=stall_db, episode_length=64,
                       hop_sizes=(1, 4, 16))
    assert env.num_actions == 2 * env.m * 3
    rng = np.random.default_rng(0)
    for seed in range(2):
        ref = dataflow_reference(prog, input_seed=seed)
        env.reset()
        for _ in range(50):
            va = env.valid_actions()
            if not va:
                break
            env.step(int(rng.choice(va)))
        got = Machine().run(env.program, input_seed=seed).outputs
        assert got == ref


def test_macro_move_applies_multiple_hops(stall_db, kernel_programs):
    env = AssemblyGame(kernel_programs["fused_ff"], stall_db=stall_db,
                       episode_length=32, hop_sizes=(1, 8))
    env.reset()
    rng = np.random.default_rng(1)
    hop_counts = []
    for _ in range(30):
        va = env.valid_actions()
        big = [a for a in va if a % 2 == 1]   # hop index 1 (=8 hops)
        if not big:
            break
        env.step(int(rng.choice(big)))
        hop_counts.append(env.history[-1].hops)
    assert hop_counts and max(hop_counts) > 1


def test_warm_start_resumes_from_best(stall_db, kernel_programs):
    prog = kernel_programs["rmsnorm"]
    env = AssemblyGame(prog, stall_db=stall_db, episode_length=20,
                       warm_start=True)
    env.reset()
    rng = np.random.default_rng(2)
    for _ in range(20):
        va = env.valid_actions()
        if not va:
            break
        env.step(int(rng.choice(va)))
    best_prog = list(env.best_program)
    t0 = env.t0
    env.reset()
    # episode restarts from the incumbent best, Eq.3 T_0 stays pinned
    assert [id(i) for i in env.program] == [id(i) for i in best_prog]
    assert env.t0 == t0
    # and semantics still intact from the warm-started state
    ref = dataflow_reference(prog)
    assert Machine().run(env.program).outputs == ref
