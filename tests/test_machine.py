"""Machine-model tests: determinism, microbenchmark recovery of the hidden
latency table, stale-read semantics, counters."""

from repro.core import Machine, build_stall_table, clock_based_estimate
from repro.core.machine import dataflow_reference, true_fixed_latency
from repro.core.microbench import DEFAULT_BENCH_OPS, measure_stall_count
from repro.core.parser import parse_program

_PROG = """
[B------:R-:W-:-:S08] SMOV R2, 0x7 ;
[B------:R-:W-:-:S08] SMOV R4, 0x9 ;
[B------:R-:W-:-:S04] SADD R6, R2, R4 ;
[B------:R0:W-:-:S08] STV [R90], R6 ;
[B------:R1:W-:-:S08] CPYOUT.64 [OUT0], R6 ;
[B------:R-:W-:-:S01] EXIT ;
"""


def test_run_deterministic():
    prog = parse_program(_PROG)
    m = Machine()
    r1, r2 = m.run(prog), m.run(prog)
    assert r1.cycles == r2.cycles and r1.outputs == r2.outputs


def test_input_seed_changes_values_not_cycles():
    prog = parse_program(_PROG)
    m = Machine()
    a, b = m.run(prog, input_seed=0), m.run(prog, input_seed=1)
    assert a.cycles == b.cycles
    assert a.outputs != b.outputs


def test_stale_read_on_violated_stall():
    """Post-Kepler semantics: shrinking the producer's stall below its
    latency corrupts the consumer's value (no hardware interlock)."""
    ok = parse_program(_PROG)
    bad = parse_program(_PROG.replace("S04] SADD", "S01] SADD"))
    ref = dataflow_reference(ok)
    m = Machine()
    assert m.run(ok).outputs == ref
    assert m.run(bad).outputs != ref


def test_microbench_recovers_hidden_table():
    """Dependency-based microbenchmarking (§4.3) recovers the private
    latency table exactly — the test is the only licensed peeker."""
    table = build_stall_table()
    for op in DEFAULT_BENCH_OPS:
        assert table[op] == true_fixed_latency(op), op
    assert "SADDX" not in table  # left to the inference pass (§3.2)


def test_clock_based_underestimates():
    """Listing 7's negative result: clock reads don't wait for completion."""
    clock = clock_based_estimate("SADD")
    assert clock < true_fixed_latency("SADD")


def test_wide_op_is_slower():
    assert measure_stall_count("SMULW") == 5
    assert measure_stall_count("SMUL") == 4


def test_counters_and_noise(kernel_programs):
    prog = kernel_programs["rmsnorm"]
    res = Machine().run(prog)
    c = res.counters
    assert c["cpyin"] > 0 and c["cpyout"] > 0 and c["ldv"] > 0
    assert c["dma_bytes_in"] > 0 and 0 < c["ipc"] <= 1.0
    noisy = Machine(noise=0.05, seed=1).run(prog)
    assert noisy.cycles != res.cycles
    assert abs(noisy.cycles - res.cycles) / res.cycles < 0.5


def test_reuse_buffer_rewards_backtoback_mxm():
    base = """
[B------:R-:W-:-:S08] SMOV R10, 0x1 ;
[B------:R-:W-:-:S08] SMOV R12, 0x2 ;
[B------:R-:W-:-:S08] MXM R200, R10, R12 ;
{MID}
[B------:R-:W-:-:S08] MXM R201, R10.reuse, R12 ;
[B------:R0:W-:-:S08] CPYOUT.64 [OUT0], R201 ;
[B------:R-:W-:-:S01] EXIT ;
"""
    together = parse_program(base.replace("{MID}", ""))
    split = parse_program(base.replace(
        "{MID}", "[B------:R-:W2:-:S08] CPYIN.64 [UR2+0x0], "
                 "desc[UR16][R20.64] ; // tile=in_x:0"))
    m = Machine()
    hits_together = m.run(together).counters["mxm_reuse_hits"]
    hits_split = m.run(split).counters["mxm_reuse_hits"]
    assert hits_together == 1 and hits_split == 0
