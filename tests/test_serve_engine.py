"""Continuous-batching serve engine: per-request bit-exactness vs the
static ``generate()`` path (including under preemption and slot reuse),
KV block-pool invariants under seeded churn, weighted-fair scheduling
with starvation/budget guards, the cache-capacity admission boundary,
and the zero-measurement serve hot path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serve import (FairScheduler, KVBlockPool, PoolCapacityError,
                         PoolError, Request, ServeEngine, Tenant,
                         TrafficConfig, generate, run_load)
from repro.serve.decode import decode_step, init_caches

MAX_SEQ = 48


def _model(arch):
    cfg = get_config(arch, reduced=True)
    return cfg, lm.init_model(cfg, jax.random.PRNGKey(0))


def _ref_generation(params, cfg, prompt, n):
    """One-request-at-a-time reference: the static scanned generate()
    at the engine's cache geometry (same max_seq -> same summation
    order), returning just the generated suffix."""
    out = generate(params, cfg, np.asarray(prompt, np.int32)[None], n,
                   max_seq=MAX_SEQ)
    return np.asarray(out)[0, len(prompt):].tolist()


# ---------------------------------------------------------------------------
# continuous-batching equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen1.5-4b", "gemma3-1b", "mamba2-1.3b"])
def test_continuous_batching_bitexact_vs_sequential_generate(arch):
    """Acceptance criterion: per-request token streams under continuous
    batching (staggered arrivals, mixed lengths, slot churn) are
    bit-exact vs running each request alone through ``generate()``
    (greedy).  Covers absolute caches (qwen), ring-buffer local windows
    (gemma), and recurrent SSM state (mamba — exercises the slot-reset
    path when a freed slot is reused)."""
    cfg, params = _model(arch)
    engine = ServeEngine.from_config(cfg, params=params, max_batch=3,
                                     max_seq=MAX_SEQ, block_size=8,
                                     prefill_chunk=2)
    rng = np.random.default_rng(0)
    jobs = []
    for _ in range(4):
        plen, n = int(rng.integers(3, 14)), int(rng.integers(2, 10))
        jobs.append((rng.integers(0, cfg.vocab, plen,
                                  dtype=np.int32).tolist(), n))
    reqs = [engine.submit(p, n) for p, n in jobs[:2]]
    for _ in range(3):                       # stagger: arrive mid-flight
        engine.step()
    reqs += [engine.submit(p, n) for p, n in jobs[2:]]
    engine.run()

    for req, (prompt, n) in zip(reqs, jobs):
        assert req.output == _ref_generation(params, cfg, prompt, n), \
            f"request {req.id} diverged from sequential generate()"
        assert len(req.output) == n and not req.truncated
        assert req.ttft is not None and req.latency >= req.ttft
    assert engine.pool.stats()["free_blocks"] == engine.pool.num_blocks
    engine.pool.check()


def test_preempted_requests_resume_bitexact():
    """Recompute preemption: with the block pool oversubscribed, stalled
    requests get requeued with their generated prefix teacher-forced, and
    still finish bit-exact vs the sequential reference."""
    cfg, params = _model("qwen1.5-4b")
    engine = ServeEngine.from_config(cfg, params=params, max_batch=2,
                                     max_seq=MAX_SEQ, block_size=8,
                                     kv_blocks=7, prefill_chunk=4)
    rng = np.random.default_rng(2)
    jobs = [(rng.integers(0, cfg.vocab, 10, dtype=np.int32).tolist(), 30)
            for _ in range(3)]
    reqs = [engine.submit(p, n) for p, n in jobs]
    engine.run()
    assert engine.counters["preemptions"] > 0, "pool never pressured"
    for req, (prompt, n) in zip(reqs, jobs):
        ref = _ref_generation(params, cfg, prompt, n)
        if req.truncated:
            assert req.output == ref[:len(req.output)]
        else:
            assert req.output == ref
    engine.pool.check()


def test_gang_admission_is_static_batching():
    """admission='gang' (the bench baseline) only admits into an idle
    engine and still produces the exact sequential streams."""
    cfg, params = _model("qwen1.5-4b")
    engine = ServeEngine.from_config(cfg, params=params, max_batch=2,
                                     max_seq=MAX_SEQ, admission="gang")
    jobs = [([1, 2, 3], 5), ([4, 5, 6, 7], 4), ([8, 9], 6)]
    reqs = [engine.submit(p, n) for p, n in jobs]
    saw_full_gang = False
    while engine.active or engine.scheduler.pending():
        engine.step()
        assert engine.active <= 2
        if engine.active == 2 and engine.scheduler.pending():
            saw_full_gang = True
            assert engine.counters["admissions"] == 2  # 3rd waits for gang
    assert saw_full_gang
    for req, (prompt, n) in zip(reqs, jobs):
        assert req.output == _ref_generation(params, cfg, prompt, n)


# ---------------------------------------------------------------------------
# ragged per-row positions in decode_step
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen1.5-4b", "deepseek-v2-lite-16b"])
def test_vector_pos_decode_step_matches_scalar(arch):
    """A (B,) pos vector with equal entries is bit-identical to the
    scalar-pos path (attention and MLA latent caches)."""
    cfg, params = _model(arch)
    B, steps = 3, 5
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab, (steps, B, 1), dtype=np.int32)
    c_s = init_caches(cfg, B, MAX_SEQ)
    c_v = init_caches(cfg, B, MAX_SEQ)
    for pos in range(steps):
        t = jnp.asarray(toks[pos])
        log_s, c_s = decode_step(params, c_s, t, pos, cfg)
        log_v, c_v = decode_step(params, c_v, t,
                                 jnp.full((B,), pos, jnp.int32), cfg)
        np.testing.assert_array_equal(np.asarray(log_s), np.asarray(log_v))
    for a, b in zip(jax.tree.leaves(c_s), jax.tree.leaves(c_v)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# capacity boundary
# ---------------------------------------------------------------------------

def test_prompt_at_cache_capacity_raises_pool_capacity_error():
    """A prompt of exactly ``max_seq`` tokens must raise a typed
    PoolCapacityError at admission (pool and engine), not silently write
    out of cache range; ``max_seq - 1`` still admits and generates."""
    pool = KVBlockPool(num_slots=2, max_seq=16, block_size=4)
    assert not pool.fits(16) and not pool.fits(17) and pool.fits(15)
    with pytest.raises(PoolCapacityError):
        pool.alloc("r1", 16)
    pool.check()

    cfg, params = _model("qwen1.5-4b")
    engine = ServeEngine.from_config(cfg, params=params, max_batch=2,
                                     max_seq=16)
    with pytest.raises(PoolCapacityError):
        engine.submit(list(range(16)), 4)
    req = engine.submit(list(range(15)), 4)      # boundary-1: admissible
    engine.run()
    # positions 14 and 15 each emit one token, then a clean truncation —
    # never a clamped out-of-range cache write
    assert len(req.output) == 2 and req.truncated
    engine.pool.check()


# ---------------------------------------------------------------------------
# pool invariants
# ---------------------------------------------------------------------------

def test_pool_alloc_free_invariants_under_seeded_churn():
    pool = KVBlockPool(num_slots=4, max_seq=64, block_size=8, num_blocks=20)
    rng = np.random.default_rng(42)
    live = {}
    for i in range(400):
        if live and (rng.random() < 0.4 or not pool.free_slot_count):
            rid = rng.choice(list(live))
            pool.free(rid)
            del live[rid]
        else:
            rid, plen = f"r{i}", int(rng.integers(1, 64))
            if pool.can_admit(plen):
                t = pool.alloc(rid, plen)
                live[rid] = t
                assert t.tokens >= plen
        if live and rng.random() < 0.5:
            rid = rng.choice(list(live))
            want = int(rng.integers(1, 65))
            if pool.can_ensure(rid, want):
                assert pool.ensure(rid, want).tokens >= want
        pool.check()                      # conservation + no double-grant
    for rid in list(live):
        pool.free(rid)
    pool.check()
    s = pool.stats()
    assert s["free_blocks"] == pool.num_blocks      # no leak after churn
    assert s["free_slots"] == pool.num_slots
    assert s["allocs"] == s["frees"]


def test_pool_double_free_and_protocol_errors():
    pool = KVBlockPool(num_slots=2, max_seq=32, block_size=8)
    pool.alloc("a", 10)
    with pytest.raises(PoolError):
        pool.alloc("a", 4)               # duplicate allocation
    pool.free("a")
    with pytest.raises(PoolError):
        pool.free("a")                   # double free
    with pytest.raises(PoolError):
        pool.ensure("ghost", 8)          # unknown request
    pool.check()


def test_pool_oversubscription_runs_out_of_blocks_not_slots():
    pool = KVBlockPool(num_slots=4, max_seq=32, block_size=8, num_blocks=5)
    pool.alloc("a", 24)                  # 3 blocks
    pool.alloc("b", 16)                  # 2 blocks -> 0 free
    assert pool.free_slot_count == 2 and not pool.can_admit(1)
    with pytest.raises(PoolCapacityError):
        pool.alloc("c", 8)
    assert not pool.can_ensure("a", 32)
    with pytest.raises(PoolCapacityError):
        pool.ensure("a", 32)
    pool.free("b")
    assert pool.can_ensure("a", 32)
    pool.check()


# ---------------------------------------------------------------------------
# fair scheduler
# ---------------------------------------------------------------------------

def _drain(sched, n):
    order = []
    for _ in range(n):
        req = sched.admit_next()
        if req is None:
            break
        req.finish_time = req.submit_time + 1.0
        sched.release(req, served_tokens=req.cost)
        order.append(req.tenant)
    return order


def test_wfq_admission_tracks_weight_ratio():
    """Tenants with 3:1 weights and equal-cost backlogs get admissions in
    a 3:1 ratio over any busy window (stride scheduling)."""
    sched = FairScheduler([Tenant("a", weight=3.0), Tenant("b", weight=1.0)],
                          starvation_bound=1000)
    for i in range(40):
        sched.submit(Request(prompt=[0] * 8, max_new_tokens=8, tenant="a"))
        sched.submit(Request(prompt=[0] * 8, max_new_tokens=8, tenant="b"))
    order = _drain(sched, 24)
    assert order.count("a") == 18 and order.count("b") == 6


def test_starvation_bound_caps_low_weight_wait():
    """Weights bound *rates*, not *waits*: at a 1000:1 effective weight
    ratio pure WFQ would serve the light tenant once and then pass it
    over for ~1000 rounds.  The starvation bound instead caps every
    inter-admission gap at ``bound`` passed-over rounds."""
    bound = 4
    sched = FairScheduler([Tenant("heavy", weight=1.0),
                           Tenant("light", weight=0.001)],
                          starvation_bound=bound)
    for _ in range(30):
        sched.submit(Request(prompt=[0] * 8, max_new_tokens=8,
                             tenant="heavy"))
    for _ in range(5):
        sched.submit(Request(prompt=[0] * 8, max_new_tokens=8,
                             tenant="light"))
    order = _drain(sched, 20)
    light_pos = [i for i, t in enumerate(order) if t == "light"]
    assert len(light_pos) >= 3, f"light tenant starved: {order}"
    gaps = [b - a for a, b in zip(light_pos, light_pos[1:])]
    assert all(g <= bound + 1 for g in gaps), \
        f"light tenant waited beyond the bound: {order} (gaps {gaps})"


def test_token_budget_caps_in_flight_tokens():
    sched = FairScheduler([Tenant("a", weight=1.0, token_budget=20)])
    reqs = [sched.submit(Request(prompt=[0] * 6, max_new_tokens=4,
                                 tenant="a")) for _ in range(3)]
    assert sched.admit_next() is reqs[0]          # 10 in flight
    assert sched.admit_next() is reqs[1]          # 20 in flight: at budget
    assert sched.admit_next() is None             # over budget -> throttled
    sched.release(reqs[0], served_tokens=4)
    assert sched.admit_next() is reqs[2]          # budget freed
    table = {r["tenant"]: r for r in sched.fairness_table()}
    assert table["a"]["in_flight_tokens"] == 20


def test_preemption_requeue_is_not_double_charged():
    """A preempted request re-admits without advancing its tenant's
    virtual time again (its footprint was charged at first admission)."""
    sched = FairScheduler([Tenant("a", weight=1.0)])
    req = sched.submit(Request(prompt=[0] * 8, max_new_tokens=8, tenant="a"))
    assert sched.admit_next() is req
    v1 = sched.fairness_table()[0]["vtime"]
    sched.release(req)                            # preemption
    sched.requeue_front(req)
    assert sched.admit_next() is req
    assert sched.fairness_table()[0]["vtime"] == v1


# ---------------------------------------------------------------------------
# zero-measurement serve path + load generator
# ---------------------------------------------------------------------------

def test_serve_hot_path_zero_measurements(tmp_path, stall_db, monkeypatch):
    """Acceptance criterion: an engine constructed with a schedule cache
    resolves its whole kernel plan and serves traffic with zero
    ``Machine.run``/``Machine.time``/autotune calls — schedules reach the
    serve path as pure index lookups."""
    import sys

    from repro.core import Machine
    from repro.sched import OptimizationSession, make_budgeted_strategy
    from repro.sched.cache import ScheduleCache
    from repro.sched.session import OptimizeRequest

    session = OptimizationSession(
        strategy=make_budgeted_strategy("greedy", timesteps=64,
                                        episode_length=8),
        cache_dir=str(tmp_path / "cache"), stall_db=stall_db,
        verify_seeds=2)
    session.optimize(OptimizeRequest(kernel="rmsnorm"))

    calls = {"run": 0, "time": 0, "autotune": 0}
    real_run, real_time = Machine.run, Machine.time
    autotune_mod = sys.modules["repro.sched.autotune"]

    def counting(name, fn):
        def wrapper(*a, **kw):
            calls[name] += 1
            return fn(*a, **kw)
        return wrapper

    monkeypatch.setattr(Machine, "run", counting("run", real_run))
    monkeypatch.setattr(Machine, "time", counting("time", real_time))
    monkeypatch.setattr(autotune_mod, "autotune",
                        counting("autotune", autotune_mod.autotune))

    cfg, params = _model("qwen1.5-4b")
    engine = ServeEngine.from_config(
        cfg, params=params, max_batch=2, max_seq=32,
        schedule_cache=ScheduleCache(str(tmp_path / "cache")))
    # the tuned kernel resolved in every bucket; untuned fleet members
    # explicitly serve the -O3 baseline (None), never re-measured
    rms = {k: v for k, v in engine.plan.items()
           if (k[0] if isinstance(k, tuple) else k) == "rmsnorm"}
    assert rms and all(a is not None for a in rms.values())
    assert any(a is None for a in engine.plan.values())
    engine.submit([1, 2, 3, 4], 4)
    engine.submit([5, 6], 3)
    engine.run()
    assert calls == {"run": 0, "time": 0, "autotune": 0}


def test_load_generator_replays_seeded_trace_and_reports():
    cfg, params = _model("qwen1.5-4b")
    traffic = TrafficConfig(qps=200.0, n_requests=6, n_tenants=2,
                            prompt_len=(2, 6), output_len=(2, 5),
                            vocab=cfg.vocab, seed=3)
    engine = ServeEngine.from_config(
        cfg, params=params, max_batch=2, max_seq=24,
        tenants=[Tenant("t0", weight=2.0), Tenant("t1", weight=1.0)])
    report = run_load(engine, traffic, pace=False)
    assert report["completed"] == 6 and report["truncated"] == 0
    assert report["tokens"] > 0 and report["tokens_per_s"] > 0
    for k in ("latency_p50_s", "latency_p99_s", "ttft_p50_s"):
        assert np.isfinite(report[k]) and report[k] >= 0
    served = {r["tenant"]: r["served_tokens"]
              for r in report["stats"]["tenants"]}
    assert sum(served.values()) == report["tokens"]
    assert report["stats"]["engine"]["lane_utilization"] > 0
