"""Fast reward loop (timing-only measurement): ``Machine.time`` and the
incremental ``ScheduleTimer`` must agree *bit-exactly* with the dataflow
oracle ``Machine.run(...).cycles`` on every schedule a masked game can
reach, and the assembly game's measurement memo must be invisible to
rewards under warm starts and macro moves.

The schedule-space property test uses hypothesis when installed and a
seeded-random sweep otherwise (same driver either way)."""

import numpy as np
import pytest

from repro.core import Machine
from repro.core.env import AssemblyGame
from repro.core.game import train_on_program
from repro.core.ppo import PPOConfig
from repro.core.timing import ScheduleTimer

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

_PROP_KERNELS = ("rmsnorm", "softmax", "fused_ff", "bmm")


def _walk_and_check(prog, stall_db, seed, hop_sizes=(1,), episodes=2,
                    steps=24, checkpoint_every=8):
    """Drive a random masked game on the oracle measurement path; at every
    visited schedule assert one-shot timing AND incremental re-timing equal
    the oracle's cycle count exactly.  Returns schedules checked."""
    m = Machine()
    env = AssemblyGame(prog, stall_db=stall_db, episode_length=steps,
                       hop_sizes=hop_sizes, use_fast_measure=False)
    timer = ScheduleTimer(env.original, checkpoint_every=checkpoint_every)
    rng = np.random.default_rng(seed)
    checked = 0
    for _ in range(episodes):
        env.reset()
        for _ in range(steps):
            va = env.valid_actions()
            if not va:
                break
            env.step(int(rng.choice(va)))
            truth = m.run(env.program).cycles
            assert m.time(env.program) == truth
            assert timer.time_ids(env.id_at) == truth
            checked += 1
    assert checked > 0
    return checked


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           kernel=st.sampled_from(_PROP_KERNELS),
           hop_sizes=st.sampled_from([(1,), (1, 2, 4)]))
    def test_time_equals_run_property(seed, kernel, hop_sizes, stall_db,
                                      kernel_programs):
        _walk_and_check(kernel_programs[kernel], stall_db, seed,
                        hop_sizes=hop_sizes)

else:

    @pytest.mark.parametrize("kernel", _PROP_KERNELS)
    @pytest.mark.parametrize("seed", range(4))
    def test_time_equals_run_property(kernel, seed, stall_db,
                                      kernel_programs):
        hop_sizes = (1, 2, 4) if seed % 2 else (1,)
        _walk_and_check(kernel_programs[kernel], stall_db, seed,
                        hop_sizes=hop_sizes)


def test_time_matches_run_on_all_baselines(kernel_programs):
    m = Machine()
    for name, prog in kernel_programs.items():
        assert m.time(prog) == m.run(prog).cycles, name


def test_time_independent_of_input_seed(kernel_programs):
    """Timing never reads data values (no interlocks), so ``input_seed``
    cannot matter — the signature exists only for parity with ``run``."""
    m = Machine()
    prog = kernel_programs["rmsnorm"]
    assert m.time(prog, input_seed=0) == m.time(prog, input_seed=123)


def test_time_applies_noise_like_run(kernel_programs):
    prog = kernel_programs["ssd"]
    a = Machine(noise=0.05, seed=7).run(prog).cycles
    b = Machine(noise=0.05, seed=7).time(prog)
    assert a == b  # same RNG stream, same draw


def test_incremental_resume_uses_checkpoints(stall_db, kernel_programs):
    """A swap at position p must resume from the nearest checkpoint at or
    below p-1, not from cycle 0."""
    env = AssemblyGame(kernel_programs["softmax"], stall_db=stall_db,
                       episode_length=8)
    env.reset()
    timer = env._timer
    k = timer.k
    nh = len(env.hop_sizes)
    # pick the valid action whose slot sits deepest in the program
    va = env.valid_actions()
    assert va
    a = max(va, key=lambda x: env.slot_pos[x // (2 * nh)])
    pos = env.slot_pos[a // (2 * nh)]
    assert pos > 2 * k, "softmax should have schedulable slots beyond 2K"
    env.step(a)
    assert 0 < timer.resumed_from <= pos
    assert timer.resumed_from == ((pos - 1) // k) * k


def test_scheduletimer_rejects_bad_orders(kernel_programs):
    timer = ScheduleTimer(kernel_programs["bmm"])
    with pytest.raises(ValueError):
        timer.time_ids(np.arange(timer.n - 1))
    with pytest.raises(ValueError):
        ScheduleTimer(kernel_programs["bmm"], checkpoint_every=0)


def test_memo_invisible_under_warm_start_and_hops(stall_db, kernel_programs):
    """Fast (memoized) and oracle envs must agree step-for-step on rewards,
    cycles, termination, and the run-global best — under warm starts and
    hop_sizes=(1,2,4) — and the memo must actually get hits."""
    prog = kernel_programs["rmsnorm"]
    for hop_sizes in ((1,), (1, 2), (1, 2, 4)):
        fast = AssemblyGame(prog, stall_db=stall_db, episode_length=12,
                            warm_start=True, hop_sizes=hop_sizes)
        slow = AssemblyGame(prog, stall_db=stall_db, episode_length=12,
                            warm_start=True, hop_sizes=hop_sizes,
                            use_fast_measure=False)
        assert fast._timer is not None and slow._timer is None
        rng = np.random.default_rng(11)
        for _ in range(3):
            fast.reset()
            slow.reset()
            while True:
                va = fast.valid_actions()
                assert va == slow.valid_actions()
                if not va:
                    break
                a = int(rng.choice(va))
                _, rf, df, inf_f = fast.step(a)
                _, rs, ds, inf_s = slow.step(a)
                assert rf == rs and df == ds
                assert inf_f["cycles"] == inf_s["cycles"]
                if df:
                    break
        assert fast.best_cycles == slow.best_cycles
        assert fast.t0 == slow.t0
        # warm-start resets re-measure the incumbent: guaranteed memo hits
        assert fast.memo_hits > 0
        assert fast.memo_hits + fast.memo_misses == fast.measure_calls


def test_fast_measure_disabled_for_noisy_machines(stall_db, kernel_programs):
    """A noisy machine re-draws on every measurement; the memo would freeze
    one draw, so the fast path must bow out."""
    env = AssemblyGame(kernel_programs["ssd"], stall_db=stall_db,
                       machine=Machine(noise=0.05, seed=1))
    assert env._timer is None


def test_shared_memo_across_envs(stall_db, kernel_programs):
    """train_on_program's envs share one schedule->cycles memo: the second
    env's baseline measurement must hit the first env's entry."""
    cache = {}
    a = AssemblyGame(kernel_programs["bmm"], stall_db=stall_db,
                     measure_cache=cache, input_seed=0)
    assert (a.memo_hits, a.memo_misses) == (0, 1)
    b = AssemblyGame(kernel_programs["bmm"], stall_db=stall_db,
                     measure_cache=cache, input_seed=1)
    assert (b.memo_hits, b.memo_misses) == (1, 0)
    assert len(cache) == 1


def test_train_fast_path_reproduces_oracle_result(stall_db, kernel_programs):
    """Acceptance: same seed/config -> same best_cycles and statistics with
    measurement through the fast path, the oracle path, and the fast path
    with a measurement worker pool."""
    prog = kernel_programs["rmsnorm"]
    cfg = PPOConfig(total_timesteps=256, num_envs=4, num_steps=32,
                    episode_length=16, seed=3, warm_start=True)
    fast = train_on_program(prog, stall_db=stall_db, cfg=cfg)
    slow = train_on_program(prog, stall_db=stall_db, cfg=cfg,
                            use_fast_measure=False)
    pooled = train_on_program(prog, stall_db=stall_db, cfg=cfg,
                              measure_workers=2)
    assert fast.best_cycles == slow.best_cycles == pooled.best_cycles
    assert fast.baseline_cycles == slow.baseline_cycles
    for key in ("episodic_return", "approx_kl", "entropy", "best_cycles"):
        assert [r[key] for r in fast.stats] == [r[key] for r in slow.stats]
        assert [r[key] for r in fast.stats] == [r[key] for r in pooled.stats]
    # memo totals are surfaced per stats row and consistent
    last = fast.stats[-1]
    assert last["measure_calls"] == last["memo_hits"] + last["memo_misses"]
    assert last["memo_hits"] > 0
    assert slow.stats[-1]["memo_hits"] == 0  # oracle path: no memo


def test_time_many_batches_suffix_retiming(stall_db, kernel_programs):
    """One ScheduleTimer pass over a batch of near-permutations must return
    exactly what timing each order on its own fresh timer returns — and the
    lexicographic grouping must actually resume from shared prefixes."""
    prog = kernel_programs["bmm"]
    env = AssemblyGame(prog, stall_db=stall_db, episode_length=64)
    rng = np.random.default_rng(7)
    orders = []
    env.reset()
    for _ in range(12):
        acts = env.valid_actions()
        if not acts:
            env.reset()
            continue
        env.step(int(rng.choice(acts)))
        orders.append(env.id_at.copy())
    batch = ScheduleTimer(env.original)
    got = batch.time_many(orders)
    for order, cycles in zip(orders, got):
        assert cycles == ScheduleTimer(env.original).time_ids(order)
    # de-duplicated batches come back in input order
    got2 = batch.time_many(list(reversed(orders)))
    assert got2 == list(reversed(got))
