"""Dry-run smoke: one production-mesh cell compiles end-to-end in a
subprocess (512 virtual devices; the full 40-cell × 2-mesh sweep is run by
``python -m repro.launch.dryrun --arch all --mesh both``)."""

import pytest

_CELL_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import run_cell
r = run_cell("{arch}", "{shape}", multi_pod={multi})
assert r["status"] == "ok", r.get("error", r)
roof = r["roofline"]
assert roof["flops_global"] > 0 and roof["coll_bytes_global"] > 0
assert roof["dominant"] in ("compute", "memory", "collective")
print("CELL-OK", r["arch"], r["shape"], roof["dominant"])
"""


@pytest.mark.parametrize("arch,shape,multi", [
    ("stablelm-3b", "train_4k", False),
    ("gemma3-1b", "long_500k", True),
])
def test_dryrun_cell(arch, shape, multi, subproc):
    out = subproc(_CELL_CODE.format(arch=arch, shape=shape, multi=multi),
                  n_devices=512, timeout=1200)
    assert "CELL-OK" in out


def test_mesh_shapes(subproc):
    out = subproc("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
m2 = make_production_mesh(multi_pod=True)
assert m1.shape == {"data": 16, "model": 16} and m1.size == 256
assert m2.shape == {"pod": 2, "data": 16, "model": 16} and m2.size == 512
print("MESH-OK")
""", n_devices=512)
    assert "MESH-OK" in out


def test_collective_parser():
    from repro.launch.roofline import collective_bytes
    hlo = """
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = bf16[16,4096]{1,0} all-gather(%y), dimensions={0}
  %cp = f32[64]{0} collective-permute(%z)
  %other = f32[2,2]{1,0} add(%a, %b)
"""
    got = collective_bytes(hlo)
    assert got["all-reduce"] == 128 * 256 * 4
    assert got["all-gather"] == 16 * 4096 * 2
    assert got["collective-permute"] == 64 * 4
    assert got["total"] == sum((128 * 256 * 4, 16 * 4096 * 2, 64 * 4))


def test_jcost_trip_count_awareness():
    """The analytical cost model multiplies scan bodies by trip count —
    the property XLA's cost_analysis lacks (EXPERIMENTS.md methodology)."""
    import jax
    import jax.numpy as jnp
    from repro.launch.jcost import cost_of

    def body(x, w):
        return x @ w, None

    def f(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c2 = cost_of(f, x, jax.ShapeDtypeStruct((2, 64, 64), jnp.float32))
    c4 = cost_of(f, x, jax.ShapeDtypeStruct((4, 64, 64), jnp.float32))
    assert c4["flops"] == pytest.approx(2 * c2["flops"])
    assert c2["flops"] == pytest.approx(2 * 2 * 64 ** 3)


def test_model_flops_accounting():
    from repro.configs import get_config
    from repro.launch.dryrun import model_flops
    cfg = get_config("stablelm-3b")
    mf = model_flops(cfg, "train_4k")
    assert mf == pytest.approx(6.0 * cfg.n_active_params() * 4096 * 256)
    assert model_flops(cfg, "decode_32k") == \
        pytest.approx(2.0 * cfg.n_active_params() * 128)
